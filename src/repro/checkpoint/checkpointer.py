"""Sharded, atomic, async checkpointing with resharding restore.

Layout::

    <dir>/step_<N>/
        manifest.json      # step, mesh shape+axes, tree structure, specs
        host<k>.npz        # this host's addressable shards, flat-keyed

Commit protocol: write into ``step_<N>.tmp`` then ``os.rename`` — a crashed
save never shadows the last good checkpoint (restore picks the largest
committed step; ``latest_step(clean_stale=True)`` additionally garbage-
collects torn ``.tmp`` leftovers). ``async_save`` runs the serialization on
a background thread; the train driver only blocks on the *previous* save
(one outstanding checkpoint, like Orbax) — ``AsyncCheckpointer.submit``
exposes that one-outstanding worker thread for arbitrary flush work, which
is how the engine durability tier (``fault.recovery.DurabilityManager``)
overlaps its snapshot/WAL flushes with the jitted engine step.

Between snapshots the durability tier persists *delta* records —
``wal_<N>.npz`` files written with the same tmp→rename protocol at file
granularity (``save_delta`` / ``list_deltas`` / ``load_delta``). A delta is
a flat dict of numpy arrays plus an int metadata record; chaining/validity
is the caller's contract (``fault.recovery`` stores ``base_step`` /
``prev_covered`` in the metadata and validates the chain on recover).

Restore reads every host file it can see (single-host CPU tests see all of
them) and ``jax.device_put``s each tree leaf with the *target* sharding, so
the mesh at restore time may differ from the mesh at save time — that is the
elastic-resize path (fault tolerance §6 of DESIGN.md). Leaves of any dtype
roundtrip (bf16 stored as a uint16 view; the engine states are int32+bool
trees — see ``fault.recovery.recover`` for the engine restart path).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = leaf
    return flat


def _tree_def(tree):
    return jax.tree_util.tree_structure(tree)


def save(directory: str, step: int, tree, host_id: int = 0, num_hosts: int = 1):
    """Synchronous sharded save + atomic commit (host 0 commits)."""
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    arrays = {}
    for k, v in flat.items():
        arr = np.asarray(jax.device_get(v))
        if arr.dtype == jnp.bfloat16:
            arrays[k + "::bf16"] = arr.view(np.uint16)
        else:
            arrays[k] = arr
    np.savez(os.path.join(tmp, f"host{host_id}.npz"), **arrays)
    if host_id == 0:
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        manifest = {
            "step": step,
            "num_hosts": num_hosts,
            "keys": list(flat.keys()),
            "shapes": {k: list(np.shape(v)) for k, v in flat.items()},
            "dtypes": {k: str(jnp.asarray(v).dtype) for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            import shutil

            shutil.rmtree(final)
        os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """One outstanding async save; ``wait()`` before the next or at exit.

    ``submit`` is the general form: it runs any host-side flush callable on
    the single background worker thread (the durability tier submits both
    full snapshots and WAL-delta writes through it, so at most one flush is
    ever in flight and flushes overlap the device step). ``save`` is the
    train-path convenience wrapper that device_gets the tree synchronously
    (so the donated device buffers may be reused immediately) and serializes
    on the worker.
    """

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    def submit(self, work) -> None:
        """Run ``work()`` on the background thread after joining the
        previous one; its exception (if any) surfaces on the next wait()."""
        self.wait()

        def runner():
            try:
                work()
            except BaseException as e:  # surfaced on next wait()
                self._err = e

        self._thread = threading.Thread(target=runner, daemon=True)
        self._thread.start()

    def save(self, step: int, tree):
        host_tree = jax.tree_util.tree_map(np.asarray, jax.device_get(tree))
        self.submit(lambda: save(self.directory, step, host_tree))

    def busy(self) -> bool:
        """True while the previous flush is still running (submit would block)."""
        return self._thread is not None and self._thread.is_alive()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err


def clean_stale(directory: str) -> list[str]:
    """Remove torn flush leftovers: ``step_*.tmp`` dirs (snapshot was being
    written when the process died) and ``wal_*.npz.tmp`` files (torn delta).
    Returns the names removed. Safe to call any time — committed state is
    never named ``*.tmp``."""
    import shutil

    removed = []
    if not os.path.isdir(directory):
        return removed
    for name in os.listdir(directory):
        path = os.path.join(directory, name)
        if name.startswith("step_") and name.endswith(".tmp") and os.path.isdir(path):
            shutil.rmtree(path)
            removed.append(name)
        elif name.startswith("wal_") and name.endswith(".npz.tmp") and os.path.isfile(path):
            os.remove(path)
            removed.append(name)
    return removed


def latest_step(directory: str, clean_stale_files: bool = False) -> Optional[int]:
    """Largest committed snapshot step, or None. A leftover ``step_N.tmp``
    from a crashed save is never a candidate (no rename happened); with
    ``clean_stale_files=True`` such leftovers (and torn ``wal_*.npz.tmp``)
    are also deleted, which is what the restart path wants."""
    if not os.path.isdir(directory):
        return None
    if clean_stale_files:
        clean_stale(directory)
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def save_delta(directory: str, step: int, arrays: dict[str, np.ndarray], meta: dict[str, int]) -> str:
    """Atomically commit one WAL delta record covering engine step ``step``.

    ``arrays`` is a flat dict of numpy arrays; ``meta`` a flat dict of ints
    (stored as a structured side array). Written as ``wal_<step>.npz.tmp``
    then renamed — a crash mid-write leaves only a ``.tmp`` that
    ``clean_stale`` removes and ``list_deltas`` never returns."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"wal_{step}.npz")
    tmp = final + ".tmp"
    meta_keys = sorted(meta)
    payload = dict(arrays)
    payload["__meta_keys__"] = np.array(meta_keys, dtype=np.str_)
    payload["__meta_vals__"] = np.array([int(meta[k]) for k in meta_keys], dtype=np.int64)
    with open(tmp, "wb") as f:
        np.savez(f, **payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    return final


def list_deltas(directory: str) -> list[int]:
    """Sorted steps of committed WAL delta records (``.tmp`` never listed)."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("wal_") and name.endswith(".npz"):
            steps.append(int(name[len("wal_"):-len(".npz")]))
    return sorted(steps)


def load_delta(directory: str, step: int) -> tuple[dict[str, np.ndarray], dict[str, int]]:
    """Load one committed delta record → (arrays, meta)."""
    with np.load(os.path.join(directory, f"wal_{step}.npz")) as z:
        meta_keys = [str(k) for k in z["__meta_keys__"]]
        meta_vals = z["__meta_vals__"]
        meta = {k: int(v) for k, v in zip(meta_keys, meta_vals)}
        arrays = {k: z[k] for k in z.files if not k.startswith("__meta_")}
    return arrays, meta


def restore(directory: str, step: int, like, shardings=None):
    """Load a checkpoint into the structure of ``like`` (a pytree of arrays
    or ShapeDtypeStructs). ``shardings``: matching pytree of shardings for
    the *target* mesh (elastic restore) or None for host-local arrays."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data: dict[str, np.ndarray] = {}
    for name in sorted(os.listdir(path)):
        if name.endswith(".npz"):
            with np.load(os.path.join(path, name)) as z:
                for k in z.files:
                    if k.endswith("::bf16"):
                        data[k[: -len("::bf16")]] = z[k].view(jnp.bfloat16)
                    else:
                        data[k] = z[k]
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
    flat_sh = _flatten(shardings) if shardings is not None else {k: None for k in flat_like}
    out = {}
    for k, proto in flat_like.items():
        arr = data[k]
        if tuple(arr.shape) != tuple(proto.shape):
            raise ValueError(f"shape mismatch for {k}: {arr.shape} vs {proto.shape}")
        out[k] = jax.device_put(arr, flat_sh[k]) if flat_sh[k] is not None else jnp.asarray(arr)
    return rebuild(like, out), manifest["step"]


def rebuild(like, flat: dict[str, Any]):
    """Unflatten a ``_flatten``-keyed dict back into ``like``'s structure."""
    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    ordered = []
    for path, _ in leaves_with_path:
        key = "/".join(str(getattr(kk, "key", getattr(kk, "idx", kk))) for kk in path)
        ordered.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, ordered)
