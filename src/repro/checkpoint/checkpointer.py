"""Sharded, atomic, async checkpointing with resharding restore.

Layout::

    <dir>/step_<N>/
        manifest.json      # step, mesh shape+axes, tree structure, specs
        host<k>.npz        # this host's addressable shards, flat-keyed

Commit protocol: write into ``step_<N>.tmp`` then ``os.rename`` — a crashed
save never shadows the last good checkpoint (restore picks the largest
committed step). ``async_save`` runs the serialization on a background
thread; the train driver only blocks on the *previous* save (one outstanding
checkpoint, like Orbax).

Restore reads every host file it can see (single-host CPU tests see all of
them) and ``jax.device_put``s each tree leaf with the *target* sharding, so
the mesh at restore time may differ from the mesh at save time — that is the
elastic-resize path (fault tolerance §6 of DESIGN.md).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = leaf
    return flat


def _tree_def(tree):
    return jax.tree_util.tree_structure(tree)


def save(directory: str, step: int, tree, host_id: int = 0, num_hosts: int = 1):
    """Synchronous sharded save + atomic commit (host 0 commits)."""
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    arrays = {}
    for k, v in flat.items():
        arr = np.asarray(jax.device_get(v))
        if arr.dtype == jnp.bfloat16:
            arrays[k + "::bf16"] = arr.view(np.uint16)
        else:
            arrays[k] = arr
    np.savez(os.path.join(tmp, f"host{host_id}.npz"), **arrays)
    if host_id == 0:
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        manifest = {
            "step": step,
            "num_hosts": num_hosts,
            "keys": list(flat.keys()),
            "shapes": {k: list(np.shape(v)) for k, v in flat.items()},
            "dtypes": {k: str(jnp.asarray(v).dtype) for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            import shutil

            shutil.rmtree(final)
        os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """One outstanding async save; ``wait()`` before the next or at exit."""

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    def save(self, step: int, tree):
        self.wait()
        host_tree = jax.tree_util.tree_map(np.asarray, jax.device_get(tree))

        def work():
            try:
                save(self.directory, step, host_tree)
            except BaseException as e:  # surfaced on next wait()
                self._err = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, step: int, like, shardings=None):
    """Load a checkpoint into the structure of ``like`` (a pytree of arrays
    or ShapeDtypeStructs). ``shardings``: matching pytree of shardings for
    the *target* mesh (elastic restore) or None for host-local arrays."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data: dict[str, np.ndarray] = {}
    for name in sorted(os.listdir(path)):
        if name.endswith(".npz"):
            with np.load(os.path.join(path, name)) as z:
                for k in z.files:
                    if k.endswith("::bf16"):
                        data[k[: -len("::bf16")]] = z[k].view(jnp.bfloat16)
                    else:
                        data[k] = z[k]
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}...")
    flat_sh = _flatten(shardings) if shardings is not None else {k: None for k in flat_like}
    out = {}
    for k, proto in flat_like.items():
        arr = data[k]
        if tuple(arr.shape) != tuple(proto.shape):
            raise ValueError(f"shape mismatch for {k}: {arr.shape} vs {proto.shape}")
        out[k] = jax.device_put(arr, flat_sh[k]) if flat_sh[k] is not None else jnp.asarray(arr)
    # rebuild the tree
    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    ordered = []
    for path, _ in leaves_with_path:
        key = "/".join(str(getattr(kk, "key", getattr(kk, "idx", kk))) for kk in path)
        ordered.append(out[key])
    return jax.tree_util.tree_unflatten(treedef, ordered), manifest["step"]
