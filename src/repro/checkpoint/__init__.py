from repro.checkpoint.checkpointer import (
    AsyncCheckpointer,
    clean_stale,
    latest_step,
    list_deltas,
    load_delta,
    rebuild,
    restore,
    save,
    save_delta,
)
from repro.checkpoint.elastic import resume, shardings_for
from repro.checkpoint.wal import (
    SegmentWriter,
    gc_covered,
    list_segments,
    read_segments,
    scan_segment,
)
