from repro.checkpoint.checkpointer import AsyncCheckpointer, latest_step, restore, save
from repro.checkpoint.elastic import resume, shardings_for
